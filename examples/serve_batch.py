"""Batched serving example: continuous-batching engine over a small
decoder, several concurrent requests with different prompt lengths.

    PYTHONPATH=src python examples/serve_batch.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import transformer as TF
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = dataclasses.replace(reduced(get_config("glm4-9b")),
                              max_seq_len=256)
    key = jax.random.PRNGKey(0)
    params = TF.init_params(key, cfg)
    engine = ServeEngine(params, cfg, batch_slots=4, max_len=128,
                         dtype=jnp.float32)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        8 + 4 * i).astype(np.int32),
                    max_new_tokens=12)
            for i in range(6)]
    for r in reqs:
        engine.submit(r)

    ticks = 0
    while engine.waiting or any(engine.active):
        engine.step()
        ticks += 1
    # long-running step() loops must drain periodically so retired
    # requests do not accumulate in the engine
    done = {r.rid: r for r in engine.drain_retired()}
    for rid in sorted(done):
        r = done[rid]
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    print(f"served {len(done)} requests in {ticks} engine ticks "
          f"(batched decode, {engine.slots} slots)")


if __name__ == "__main__":
    main()
