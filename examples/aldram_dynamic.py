"""Closed-loop AL-DRAM demo: the ONLINE mechanism, end to end.

Profiles the module population, stacks the per-bin all-module-safe
timing rows (JEDEC fallback last), and replays the 35-workload pool
with the controller's temperature-bin switching running INSIDE the
traced scan — per-request RC temperature sensing, conservative
round-up, down-switch hysteresis — under dynamic ambient scenarios
(steady, diurnal ramp, cooling failure, bursty), bracketed by the
static-worst-case and oracle deployments.  Three traced dispatches
for the whole campaign.

    PYTHONPATH=src python examples/aldram_dynamic.py [--fast]
"""

import argparse
import json
import os
import sys

# the benchmark modules live at the repo root, not next to this script
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    from benchmarks.common import population, profiler
    from repro.core.aldram import ALDRAMController, default_scenarios
    from repro.core.sim_engine import SimEngine

    pop = population(args.fast)
    ctrl = ALDRAMController(profiler(args.fast))
    print("== profiling the population ==")
    ctrl.profile(pop)
    rows, bins = ctrl.table.safe_stack()
    print("bin edges (C):", list(map(float, bins)))
    print("table stack (trcd, tras, twr, trp | trefi, tcl), JEDEC last:")
    for r in rows:
        print("  ", [round(float(x), 2) for x in r])

    print("== adaptive replay under dynamic thermal scenarios ==")
    engine = SimEngine()
    res = ctrl.evaluate_dynamic(pop, scenarios=default_scenarios(),
                                n=1024 if args.fast else 4096,
                                engine=engine)
    print(json.dumps(res["per_scenario"], indent=1))
    print(f"replay dispatches: {engine.dispatch_count} "
          "(1 adaptive grid + 1 static bracket)")
    for name, d in res["per_scenario"].items():
        gap = d["oracle_gmean"] - d["adaptive_gmean"]
        print(f"{name:>18}: adaptive {d['adaptive_gmean']:+.1%} vs "
              f"static-worst {d['static_worst_gmean']:+.1%} "
              f"(hysteresis costs {gap:+.2%})")


if __name__ == "__main__":
    main()
