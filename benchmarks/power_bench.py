"""Sec. 7 power analysis: AL-DRAM reduces DRAM power ~5.8% (shorter
tRAS active windows + runtime speedup amortising background power)."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.power import power_reduction


def run(fast: bool = False) -> dict:
    with timed() as t:
        res = power_reduction()
    emit("sec7_power", t.us,
         "power_reduction={:.1%}(paper 5.8%)|per_access={:.1%}".format(
             res["power_reduction"], res["per_access_reduction"]))
    return res


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
