"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod mesh:
    compute    = flops / peak_FLOPs        (per device, scan-aware)
    memory     = hbm_bytes / HBM_bw        (upper bound: CPU-backend
                                            fusion boundaries; TPU
                                            fusion is tighter)
    collective = collective_bytes / link_bw (per-device op-result bytes)

plus MODEL_FLOPS (6*N_active*D [+ attention]) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs, which exposes remat/dispatch overhead.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (DESIGN.md §8).
"""

from __future__ import annotations

import json

from repro.configs import get_config
from repro.configs.registry import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256


def model_flops(arch: str, shape_name: str) -> float:
    """Useful model flops per device per step (forward [+backward])."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        per_tok = cfg.flops_per_token(shape.seq_len)   # forward only
        return 3.0 * per_tok * tokens / CHIPS          # fwd + bwd = 3x fwd
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return cfg.flops_per_token(shape.seq_len) * tokens / CHIPS
    # decode: one token per sequence; attention reads the whole cache
    return cfg.flops_per_token(shape.seq_len) * shape.global_batch / CHIPS


def analyze_cell(rec: dict) -> dict | None:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    cost = rec.get("cost") or {}
    flops = cost.get("flops") or 0.0
    hbm = cost.get("hbm_bytes") or 0.0
    coll = cost.get("collective_bytes", 0.0) or sum(
        rec.get("collectives", {}).values())
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / LINK_BW
    dominant = max((("compute", t_c), ("memory", t_m),
                    ("collective", t_x)), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": (mf / flops) if flops else 0.0,
        "peak_gb": (rec.get("memory", {}).get("peak_bytes") or 0) / 2**30,
        "roofline_fraction": t_c / max(t_c, t_m, t_x) if max(
            t_c, t_m, t_x) > 0 else 0.0,
    }


def table(results_path: str = "dryrun_results.json",
          mesh: str = "16x16") -> list[dict]:
    rows = []
    with open(results_path) as f:
        for rec in json.load(f):
            if rec.get("mesh") != mesh:
                continue
            row = analyze_cell(rec)
            if row:
                rows.append(row)
            elif rec.get("skipped"):
                rows.append({"arch": rec["arch"], "shape": rec["shape"],
                             "mesh": rec["mesh"], "skipped": True})
    return rows


def markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful ratio | peak GB |\n|---|---|---|---|---|"
           "---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (full attention) | — | — |")
            continue
        lines.append(
            "| {arch} | {shape} | {compute_s:.3f} | {memory_s:.3f} | "
            "{collective_s:.3f} | {dominant} | {useful_ratio:.2f} | "
            "{peak_gb:.1f} |".format(**r))
    return "\n".join(lines)


def run(fast: bool = False):
    from benchmarks.common import emit, timed
    with timed() as t:
        rows = table()
    analyzed = [r for r in rows if not r.get("skipped")]
    dom = {}
    for r in analyzed:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    emit("roofline_table", t.us,
         f"cells={len(rows)}|dominant:{dom}|"
         f"worst_useful_ratio={min((r['useful_ratio'] for r in analyzed), default=0):.2f}")
    return rows


if __name__ == "__main__":
    print(markdown(run()))
