"""Subarray-region timing hierarchy: price every spatial resolution
level of the profile->table->replay stack in ONE compressed campaign.

The per-bank table (`fig_bank`) is the coarsest spatial refinement the
design-induced-variation follow-up (Lee et al.) motivates: within a
bank, cells near the sense amplifiers / wordline drivers are faster
than the far end, so a finer-than-bank (subarray-region) table
recovers margin the bank envelope still gives away.  This bench
closes that loop at FULL depth: profile the population at 8 regions
per bank (the region axis rides the SAME fused campaign dispatch),
derive the 2- and 4-region tables bit-exactly from the stored
campaign, and replay the workload pool under module / bank / region-2
/ region-4 / region-8 rows simultaneously — the whole resolution
sweep is a [rows, U, 6] MASK-COMPRESSED unique-row stack plus one
[banks * regions] index map gathered in-scan, so it still costs
exactly one synthesis + one replay dispatch (``dispatches=2`` in the
derived CSV column, asserted by CI).

Asserted acceptance: the table-level mean timing reductions are
MONOTONE in resolution for both tests (structural — every finer
envelope contains its coarser group's; the system-side gmean speedups
are reported but NOT asserted monotone, the per-op argmin-latency
choice does not guarantee it), and the 8-region store compresses
below 0.5 of the dense (banks x regions) layout."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, spatial_campaign

LEVELS = (2, 4, 8)
REGIONS = 8


def run(fast: bool = False) -> dict:
    ctrl, res, dispatches, us = spatial_campaign(
        fast, lambda c, pop, engine, n:
            c.evaluate_region_system(pop, n=n, engine=engine,
                                     levels=LEVELS),
        regions=REGIONS)

    # acceptance 1: monotone recovery per resolution level, asserted
    # on the select-metric latency-sum reductions (structural)
    red = res["reductions"]
    for op, d in red.items():
        seq = [d["module"], d["bank"]] + [d[f"region{lv}"]
                                          for lv in LEVELS]
        for a, b in zip(seq, seq[1:]):
            assert b >= a - 1e-9, (op, seq)
    # acceptance 2: the finest store stays deployable — well under
    # half the dense (banks x regions) rows
    ratios = res["compression_ratio"]
    assert ratios[REGIONS] < 0.5, ratios
    # acceptance 3: the whole resolution sweep rode ONE synthesis +
    # ONE replay dispatch
    assert dispatches == 2, dispatches

    hot = res["temps"][-1]
    pt = res["per_temp"][hot]
    mean_gain = float(np.mean(
        [res["per_temp"][tc][f"region{REGIONS}_all_gmean"]
         - res["per_temp"][tc]["bank_all_gmean"]
         for tc in res["temps"]]))
    emit("fig_region_hierarchy", us,
         "read_red=mod {:.1%}/bank {:.1%}/r2 {:.1%}/r4 {:.1%}/r8 "
         "{:.1%}|write_red=bank {:.1%}/r8 {:.1%}|ratio8={:.3f}|"
         "ratio4={:.3f}|U={}|all35@{:.0f}C=bank {:.1%}/r8 {:.1%}|"
         "mean_r8_delta={:+.2%}|dispatches={}".format(
             red["read"]["module"], red["read"]["bank"],
             red["read"]["region2"], red["read"]["region4"],
             red["read"]["region8"], red["write"]["bank"],
             red["write"]["region8"], ratios[REGIONS], ratios[4],
             ctrl.table.n_unique, hot, pt["bank_all_gmean"],
             pt[f"region{REGIONS}_all_gmean"], mean_gain, dispatches))
    res["dispatches"] = {"total": dispatches}
    res["mean_region_delta"] = mean_gain
    res["compression_ratio"] = {str(k): v for k, v in ratios.items()}
    return res


if __name__ == "__main__":
    import json
    r = run(fast=True)
    print(json.dumps({"reductions": r["reductions"],
                      "compression_ratio": r["compression_ratio"],
                      "mean_region_delta": r["mean_region_delta"]},
                     indent=1))
