"""Sec. 7.2: interaction of simultaneous timing reductions — reducing
one parameter shrinks the opportunity to reduce another.  We trace the
per-module (tRAS_min | tRP) frontier: the minimal passing tRAS as tRP
is reduced — then replay the whole frontier through ONE batched
`SimEngine` campaign to price each profiling-feasible point in
system-level latency (every frontier row is one timing column of the
same replay dispatch)."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, population, profiler, timed
from repro.core import dram_sim
from repro.core.sim_engine import SimEngine, SimSpec
from repro.core.sweep import Op, SweepSpec
from repro.core.timing import DDR3_1600, stack_timing


def run(fast: bool = False) -> dict:
    pop = population(fast)
    prof = profiler(fast)
    with timed() as t:
        rp_read, _ = prof.refresh_campaign(pop, 85.0)
        combos = prof.combo_grid(Op.READ)
        res = prof.engine.sweep(pop, SweepSpec.single(
            Op.READ, combos, (55.0,), rp_read.safe))
        ok = res.ok[0][:, 0, :]        # [modules, combos]
        frontier = {}
        for trp in sorted(set(combos[:, 3])):
            sel = combos[:, 3] == trp
            # min passing tRAS at this tRP per module (vectorised over
            # modules); skip tRP levels that fail for most modules
            tras = np.where(ok[:, sel], combos[sel, 1][None, :], np.inf)
            tras_min = np.where(ok[:, sel].any(1), tras.min(1), np.nan)
            if np.isnan(tras_min).mean() < 0.5:
                frontier[float(trp)] = float(np.nanmedian(tras_min))
        # system-level price of every frontier point: one replay
        # dispatch sweeps all (tRP, tRAS_min) rows over one trace
        rows = stack_timing([
            dataclasses.replace(DDR3_1600, trp=trp, tras=tras)
            for trp, tras in sorted(frontier.items())])
        trace = dram_sim.synth_trace(jax.random.PRNGKey(0),
                                     2048 if fast else 8192, row_hit=0.5)
        engine = SimEngine()
        sim = engine.run(SimSpec(traces=(trace,), timings=rows))
        sys_lat = sim.mean_latency_ns[0, 0]          # [frontier points]
    trps = sorted(frontier)
    monotone = all(frontier[a] >= frontier[b] - 1e-6
                   for a, b in zip(trps, trps[1:]))
    emit("sec72_multi_timing_interaction", t.us,
         f"tras_min@trp{{{trps[0]:.2f}}}={frontier[trps[0]]:.1f}ns vs "
         f"@trp{{{trps[-1]:.2f}}}={frontier[trps[-1]]:.1f}ns|"
         f"interaction={'confirmed' if monotone else 'NOT confirmed'}|"
         f"sys_lat={sys_lat.min():.1f}..{sys_lat.max():.1f}ns"
         f"|replay_dispatches={engine.dispatch_count}")
    return {"frontier": frontier, "monotone": monotone,
            "system_latency_ns": {t_: float(l) for t_, l
                                  in zip(sorted(frontier), sys_lat)}}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
