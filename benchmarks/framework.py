"""Framework-layer benchmarks: straggler mitigation win, gradient
compression ratios, kernel micro-sweeps (interpret-mode correctness
cost), serving engine throughput on CPU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed


def run_straggler(fast: bool = False) -> dict:
    from repro.runtime.straggler import simulate
    with timed() as t:
        res = simulate(n_nodes=32 if fast else 64,
                       warmup=150 if fast else 300,
                       steps=150 if fast else 300)
    emit("runtime_straggler_adaptive", t.us,
         "recall {:.0%}->{:.0%}|detect_excess {:.0f}ms->{:.0f}ms".format(
             res["static"]["recall"], res["adaptive"]["recall"],
             res["static"]["detect_excess_ms"],
             res["adaptive"]["detect_excess_ms"]))
    return res


def run_compression(fast: bool = False) -> dict:
    from repro.runtime.compression import (topk_compress, topk_init,
                                           topk_wire_bytes)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1024, 512))}
    state = topk_init(g)
    with timed() as t:
        sent, state = topk_compress(g, state, ratio=0.01)
        jax.block_until_ready(sent)
    dense = 4 * 1024 * 512
    wire = topk_wire_bytes(g, 0.01)
    emit("runtime_grad_compression", t.us,
         f"wire_bytes={wire}|dense={dense}|ratio={dense / wire:.0f}x")
    return {"wire": wire, "dense": dense}


def run_pipeline(fast: bool = False) -> dict:
    from repro.data.pipeline import AdaptivePrefetcher, SyntheticLM
    pf = AdaptivePrefetcher(iter(SyntheticLM(1000, 128, 8)),
                            static_depth=16, step_time_s=0.002)
    with timed() as t:
        for _ in range(100):
            pf.get()
    pf.refit()
    emit("data_adaptive_prefetch", t.us,
         f"depth={pf.depth}(static 16)|"
         f"memory_saving={1 - pf.depth / 16:.0%}")
    pf.stop()
    return {"depth": pf.depth}


def run(fast: bool = False):
    return {
        "straggler": run_straggler(fast),
        "compression": run_compression(fast),
        "pipeline": run_pipeline(fast),
    }


if __name__ == "__main__":
    run()
