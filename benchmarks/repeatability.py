"""Sec. 7.6: repeatability of cell failures under reduced timings.

The paper repeats failing tests (same test, new data patterns,
different timing combos, read/write) and finds >95% of erroneous cells
fail consistently.  We model per-test operational noise (power/beat
noise on the sense margin) on top of the deterministic per-cell margin
and measure the fraction of failing cells that fail in >= 9/10 repeats.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, population, timed
from repro.core import timing as T
from repro.core.calibration import CALIBRATED_CONSTANTS
from repro.core.sweep import MarginEngine

MARGIN_NOISE = 0.02      # operational noise, in margin units


def run(fast: bool = False, repeats: int = 10) -> dict:
    pop = population(fast)
    eng = MarginEngine(constants=CALIBRATED_CONSTANTS, impl="ref")
    # a deliberately aggressive combo so a fraction of cells fail
    combo = np.asarray(T.DDR3_1600.as_array())[None, :].copy()
    combo[0, :4] *= [0.7, 0.45, 0.40, 0.60]
    combo[0, 4] = 256.0     # stress the retention margin too
    with timed() as t:
        r, w = eng.margins(pop.flat_cells(), combo, temp_c=55.0)
        margin = np.minimum(r, w)[:, 0]
        rng = np.random.default_rng(0)
        fails = np.stack([
            (margin + rng.normal(0, MARGIN_NOISE, margin.shape)) < 0
            for _ in range(repeats)])
    ever = fails.any(0)
    consistent = (fails.sum(0) >= repeats - 1) & ever
    frac = consistent.sum() / max(ever.sum(), 1)
    out = {"failing_cells": int(ever.sum()),
           "repeatable_fraction": float(frac)}
    emit("sec76_repeatability", t.us,
         f"repeatable={frac:.1%}(paper >95%)|failing={int(ever.sum())}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
