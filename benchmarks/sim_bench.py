"""Device-resident campaign fast path: end-to-end pipeline benchmark.

Replays the Fig. 4-scale campaign — the paper's 35 multi-core
workload traces (n = 8192 requests) x 2 FR-FCFS scheduling policies
(16- and 64-entry transaction queues, the range real DDR3/4
controllers ship) x 16 stacked timing rows — through both SimEngine
pipelines and reports the end-to-end wall-clock ratio:

  * reference — the pre-fast-path pipeline exactly as PR 2/3 ran it:
    `pack()` materializes FR-FCFS issue orders with the O(N * window)
    pure-Python loop (the cross-call reorder cache is cleared each
    rep, faithful to the per-call-only caching it used to have), ONE
    replay dispatch, raw [T, P, S, N] latency transfer, host numpy
    `_masked_stats`.
  * fast — SimEngine defaults: the FR-FCFS prepass AND the masked
    mean/p99 reductions ride INSIDE the one replay dispatch
    (`reorder="device"`, `stats="device"`), and only [T, P, S]-shaped
    summaries cross the host boundary.

Both pipelines share the same jitted replay core (bit-identical raw
latencies), so the ratio isolates what the fast path eliminates: the
host prepass, the host reductions and the O(grid * N) transfer.
Wall times are medians over `reps` runs after an untimed compile
warm-up.  The bench asserts the acceptance contract — device stats
within 1e-5 relative of the host reference, one replay launch per
campaign — and the ``dispatches=1`` CSV field plus the committed
``BENCH_sim_bench.json`` wall-time baseline are checked by CI.
"""

from __future__ import annotations

import statistics
import time

import numpy as np

from benchmarks.common import emit


def run(fast: bool = False) -> dict:
    from repro.core import dram_sim, perf_model
    from repro.core.dram_sim import Policy, Trace
    from repro.core.sim_engine import SimEngine, SimSpec
    from repro.core.timing import DDR3_1600, stack_timing

    n = 1024 if fast else 8192
    n_rows = 8 if fast else 16
    reps = 2 if fast else 3

    # the multi-core half of the Fig. 4 pool (rows 35:70 of the
    # batched synthesis — one traced dispatch)
    tb = perf_model.trace_batch(n=n, seed=0)
    traces = Trace(*(np.asarray(f)[35:70] for f in tb))
    rows = stack_timing([DDR3_1600.scaled(f, f, f, f)
                         for f in np.linspace(1.0, 0.6, n_rows)])
    policies = (Policy(reorder_window=16), Policy(reorder_window=64))
    spec = SimSpec(traces=traces, timings=rows, policies=policies)

    fast_eng = SimEngine()                                 # device/device
    ref_eng = SimEngine(stats="host", reorder="host")      # the old path

    fast_eng.run(spec)                       # untimed compile warm-up
    dram_sim._REORDER_CACHE.clear()
    res_ref = ref_eng.run(spec)

    t_fast = []
    for _ in range(reps):
        t0 = time.monotonic()
        res_fast = fast_eng.run(spec)
        t_fast.append(time.monotonic() - t0)
    t_ref = []
    for _ in range(reps):
        # pre-fast-path pack() re-paid the Python reorder every call
        dram_sim._REORDER_CACHE.clear()
        t0 = time.monotonic()
        res_ref = ref_eng.run(spec)
        t_ref.append(time.monotonic() - t0)

    med_fast = statistics.median(t_fast)
    med_ref = statistics.median(t_ref)
    speedup = med_ref / med_fast

    # acceptance: device stats within 1e-5 relative of the host
    # reference, and the whole campaign is ONE replay launch
    rel = max(
        float(np.abs(res_fast.mean_latency_ns
                     / res_ref.mean_latency_ns - 1.0).max()),
        float(np.abs(res_fast.p99_latency_ns
                     / res_ref.p99_latency_ns - 1.0).max()))
    assert rel <= 1e-5, rel
    assert np.array_equal(res_fast.total_ns, res_ref.total_ns)
    assert res_fast.latencies is None, "collect-gated output leaked"
    dispatches_per_run = 1                  # pinned by the spy tests
    assert fast_eng.dispatch_count == 1 + reps

    emit("sim_fastpath_campaign", med_fast * 1e6,
         "speedup={:.1f}x|ref={:.2f}s|fast={:.2f}s|grid=35x2x{}|n={}|"
         "stats_rel={:.1e}|dispatches={}".format(
             speedup, med_ref, med_fast, n_rows, n, rel,
             dispatches_per_run))
    return {
        "speedup": speedup, "ref_s": med_ref, "fast_s": med_fast,
        "ref_s_all": t_ref, "fast_s_all": t_fast,
        "stats_rel_err": rel, "n": n,
        "grid": f"35x2x{n_rows}",
        "windows": [p.reorder_window for p in policies],
        "dispatches": {"replay_per_run": dispatches_per_run,
                       "synth": 1},
    }


if __name__ == "__main__":
    import json
    print(json.dumps({k: v for k, v in run().items()
                      if not k.endswith("_all")}, indent=1))
