"""Device-resident campaign fast path: end-to-end pipeline benchmark.

Replays the Fig. 4-scale campaign — the paper's 35 multi-core
workload traces (n = 8192 requests) x 2 FR-FCFS scheduling policies
(16- and 64-entry transaction queues, the range real DDR3/4
controllers ship) x 16 stacked timing rows — through three SimEngine
pipelines and reports the end-to-end wall-clock ratios:

  * reference — the pre-fast-path pipeline exactly as PR 2/3 ran it:
    `pack()` materializes FR-FCFS issue orders with the O(N * window)
    pure-Python loop (the cross-call reorder cache is cleared each
    rep, faithful to the per-call-only caching it used to have), ONE
    replay dispatch, raw [T, P, S, N] latency transfer, host numpy
    `_masked_stats`.
  * fast — the PR 4 fast path on materialized traces: the FR-FCFS
    prepass AND the masked mean/p99 reductions ride INSIDE the one
    replay dispatch (`reorder="device"`, `stats="device"`), and only
    [T, P, S]-shaped summaries cross the host boundary.  Its wall
    time (`fast_s`) is the committed-baseline regression gate in CI.
  * fused — the trace axis is a declarative `dram_sim.SynthSpec`, so
    synthesis + FR-FCFS + replay + statistics are truly ONE dispatch
    (`dispatches=1` total, `synth_dispatch_count` never moves); the
    FR-FCFS pending buffer shrinks to its EXACT slack-horizon bound,
    and the replay core (scan / scheduler-fused merged scan / Pallas
    kernel, Pallas lane-block size, fusion on/off) is AUTOTUNED per
    backend and campaign size by `SimEngine.autotune` during the
    untimed warm-up.

All pipelines replay the same multiset of requests (threefry makes
the in-dispatch synthesis bit-identical to the materialized batch),
so the ratios isolate what each stage eliminates.  Wall times are
medians over `reps` runs after untimed compile warm-ups.  The bench
asserts the acceptance contract — device stats within 1e-5 relative
of the host reference, one dispatch per fused campaign — and the
``dispatches=1`` CSV field plus the committed ``BENCH_sim_bench.json``
wall-time baseline are checked by CI.
"""

from __future__ import annotations

import statistics
import time

import numpy as np

from benchmarks.common import emit


def run(fast: bool = False) -> dict:
    import jax

    from repro.core import dram_sim, perf_model
    from repro.core.autotune import ReplayTuner
    from repro.core.dram_sim import Policy, SynthSpec, Trace
    from repro.core.sim_engine import SimEngine, SimSpec
    from repro.core.timing import DDR3_1600, stack_timing

    n = 1024 if fast else 8192
    n_rows = 8 if fast else 16
    reps = 2 if fast else 3

    # the multi-core half of the Fig. 4 pool (rows 35:70 of the
    # batched synthesis — one traced dispatch), plus the SAME pool as
    # a declarative SynthSpec (identical fold offsets -> bit-identical
    # streams, synthesized inside the fused dispatch)
    tb = perf_model.trace_batch(n=n, seed=0)
    traces = Trace(*(np.asarray(f)[35:70] for f in tb))
    offs, rhs, wfs, ias = perf_model._pool_knobs()
    synth = SynthSpec(n=n, offsets=offs[35:], row_hits=rhs[35:],
                      write_fracs=wfs[35:], inter_arrivals=ias[35:])
    rows = stack_timing([DDR3_1600.scaled(f, f, f, f)
                         for f in np.linspace(1.0, 0.6, n_rows)])
    policies = (Policy(reorder_window=16), Policy(reorder_window=64))
    spec = SimSpec(traces=traces, timings=rows, policies=policies)
    spec_fused = SimSpec(traces=synth, timings=rows, policies=policies)

    fast_eng = SimEngine()                                 # device/device
    ref_eng = SimEngine(stats="host", reorder="host")      # the old path
    # path="" keeps the bench hermetic (no cross-run disk cache)
    fused_eng = SimEngine(backend="auto",
                          tuner=ReplayTuner(
                              platform=jax.default_backend(), path=""))

    fast_eng.run(spec)                       # untimed compile warm-up
    # untimed autotune: profiles every candidate replay config on this
    # campaign (compiling each), records the winner for backend="auto"
    tuned = fused_eng.autotune(spec_fused, reps=max(2, reps - 1))
    tuned_tag = "{}+bs{}+fuse{}".format(
        tuned.backend, tuned.block_rows or "auto",
        int(tuned.fuse_synth))
    dram_sim._REORDER_CACHE.clear()
    res_ref = ref_eng.run(spec)

    t_fast = []
    for _ in range(reps):
        t0 = time.monotonic()
        res_fast = fast_eng.run(spec)
        t_fast.append(time.monotonic() - t0)
    s0 = perf_model.synth_dispatch_count
    d0 = fused_eng.dispatch_count
    t_fused = []
    for _ in range(reps):
        t0 = time.monotonic()
        res_fused = fused_eng.run(spec_fused)
        t_fused.append(time.monotonic() - t0)
    fused_replays = fused_eng.dispatch_count - d0
    fused_synths = perf_model.synth_dispatch_count - s0
    t_ref = []
    for _ in range(reps):
        # pre-fast-path pack() re-paid the Python reorder every call
        dram_sim._REORDER_CACHE.clear()
        t0 = time.monotonic()
        res_ref = ref_eng.run(spec)
        t_ref.append(time.monotonic() - t0)

    med_fast = statistics.median(t_fast)
    med_fused = statistics.median(t_fused)
    med_ref = statistics.median(t_ref)
    speedup = med_ref / med_fast
    speedup_fused = med_ref / med_fused

    # acceptance: device stats within 1e-5 relative of the host
    # reference — for BOTH fast paths — and the fused campaign is ONE
    # dispatch TOTAL (no separate synthesis launch)
    rel = max(
        float(np.abs(res_fast.mean_latency_ns
                     / res_ref.mean_latency_ns - 1.0).max()),
        float(np.abs(res_fast.p99_latency_ns
                     / res_ref.p99_latency_ns - 1.0).max()))
    rel_fused = max(
        float(np.abs(res_fused.mean_latency_ns
                     / res_ref.mean_latency_ns - 1.0).max()),
        float(np.abs(res_fused.p99_latency_ns
                     / res_ref.p99_latency_ns - 1.0).max()))
    assert rel <= 1e-5, rel
    assert rel_fused <= 1e-5, rel_fused
    assert np.array_equal(res_fast.total_ns, res_ref.total_ns)
    np.testing.assert_allclose(res_fused.total_ns, res_ref.total_ns,
                               rtol=1e-5)
    assert res_fast.latencies is None, "collect-gated output leaked"
    assert fused_replays == reps and fused_synths == 0, \
        (fused_replays, fused_synths)
    dispatches_per_run = 1                  # pinned by the spy tests

    emit("sim_fastpath_campaign", med_fused * 1e6,
         "speedup={:.1f}x|speedup_fused={:.1f}x|vs_fast={:.2f}x|"
         "ref={:.2f}s|fast={:.2f}s|fused={:.2f}s|grid=35x2x{}|n={}|"
         "stats_rel={:.1e}|tuned={}|dispatches={}".format(
             speedup, speedup_fused, med_fast / med_fused, med_ref,
             med_fast, med_fused, n_rows, n, max(rel, rel_fused),
             tuned_tag, dispatches_per_run))
    return {
        "speedup": speedup, "ref_s": med_ref, "fast_s": med_fast,
        "fused_s": med_fused, "speedup_fused": speedup_fused,
        "speedup_vs_fast": med_fast / med_fused,
        "ref_s_all": t_ref, "fast_s_all": t_fast,
        "fused_s_all": t_fused,
        "stats_rel_err": rel, "stats_rel_err_fused": rel_fused,
        "n": n, "grid": f"35x2x{n_rows}",
        "windows": [p.reorder_window for p in policies],
        "tuned": tuned_tag,
        "dispatches": {"replay_per_run": dispatches_per_run,
                       "synth": 1,
                       "fused_total_per_run": fused_replays // reps,
                       "fused_synth": fused_synths},
    }


if __name__ == "__main__":
    import json
    print(json.dumps({k: v for k, v in run().items()
                      if not k.endswith("_all")}, indent=1))
