"""Per-bank timing tables (FLY-DRAM-style spatial variation): price
the per-bank deployment against the per-module envelope.

The AL-DRAM paper keeps one register set per (module, temperature
bin); the follow-up work it inspired (Chang et al.'s FLY-DRAM, Lee
et al.'s design-induced variation) shows the margin is *spatial* —
the weakest bank governs a module-level table, so per-bank registers
recover the latency the envelope gives away.  This bench closes that
loop on our stack: profile the population (the per-bank axis rides
the SAME fused campaign dispatch), build the all-module-safe per-bank
rows per bin, and replay the full workload pool under a
[1 + 2*bins, banks, 6] per-bank timing stack — JEDEC baseline +
per-module envelope rows (constant across banks) + per-bank rows —
in ONE synthesis + ONE replay dispatch (``dispatches=2`` in the
derived CSV column, asserted by CI).

Asserted acceptance: the table-level mean timing reductions at the
per-bank granularity are >= the per-module envelope's for BOTH tests
(structural — every bank envelope contains its module envelope), and
the whole campaign stays at 2 traced dispatches.  The replay-side
speedup deltas are reported per bin (per-bank wins wherever the weak
bank was binding; the per-op argmin-latency choice weights
tRCD/tRAS/tRP equally while replay cost is tRCD-heavy, so individual
cool bins can trade a little back).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, spatial_campaign


def run(fast: bool = False) -> dict:
    ctrl, res, dispatches, us = spatial_campaign(
        fast, lambda c, pop, engine, n:
            c.evaluate_bank_system(pop, n=n, engine=engine))

    # acceptance: per-bank mean timing reductions >= per-module, both
    # tests (structural: the bank envelope contains the module envelope)
    red = res["reductions"]
    for op, d in red.items():
        assert d["bank"] >= d["module"] - 1e-9, (op, d)
    sw = ctrl.sweep_result
    for k in range(len(sw.latency_sum)):
        assert (sw.latency_sum_bank[k]
                <= sw.latency_sum[k][:, None, :] + 1e-6).all()

    cool, hot = res["temps"][0], res["temps"][-1]
    pt = res["per_temp"]
    mean_delta = float(np.mean([d["bank_minus_module"]
                                for d in pt.values()]))
    emit("fig_bank_tables", us,
         "read_red=bank {:.1%}/module {:.1%}|write_red=bank {:.1%}/"
         "module {:.1%}|all35@{:.0f}C=bank {:.1%}/module {:.1%}|"
         "all35@{:.0f}C=bank {:.1%}/module {:.1%}|"
         "mean_bank_delta={:+.2%}|dispatches={}".format(
             red["read"]["bank"], red["read"]["module"],
             red["write"]["bank"], red["write"]["module"],
             cool, pt[cool]["bank_all_gmean"], pt[cool]["module_all_gmean"],
             hot, pt[hot]["bank_all_gmean"], pt[hot]["module_all_gmean"],
             mean_delta, dispatches))
    res["dispatches"] = {"total": dispatches}
    res["mean_bank_delta"] = mean_delta
    return res


if __name__ == "__main__":
    import json
    r = run(fast=True)
    print(json.dumps({"reductions": r["reductions"],
                      "per_temp": {str(k): v
                                   for k, v in r["per_temp"].items()},
                      "mean_bank_delta": r["mean_bank_delta"]}, indent=1))
