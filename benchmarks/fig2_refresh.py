"""Fig. 2a: maximum error-free refresh interval of a representative
module at 85C (per bank / chip / module, read & write).

Paper: read 208 ms, write 160 ms at module level; banks up to
352/256 ms; DDR3 standard 64 ms.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, population, profiler, timed


def run(fast: bool = False) -> dict:
    pop = population(fast)
    prof = profiler(fast)
    out = {}
    with timed() as t:
        # both test envelopes come out of ONE MarginEngine dispatch
        profiles = dict(zip(("read", "write"),
                            prof.refresh_campaign(pop, 85.0)))
        for op, rp in profiles.items():
            med = int(np.argsort(rp.per_module)[len(rp.per_module) // 2])
            out[op] = {
                "module_ms": float(rp.per_module[med]),
                "best_bank_ms": float(rp.per_bank[med].max()),
                "best_chip_ms": float(rp.per_chip[med].max()),
                "population_median_ms": float(np.median(rp.per_module)),
                "population_min_ms": float(rp.per_module.min()),
                "safe_ms": float(rp.safe[med]),
            }
    emit("fig2a_refresh_envelope", t.us,
         f"read={out['read']['module_ms']:.0f}ms(paper 208)|"
         f"write={out['write']['module_ms']:.0f}ms(paper 160)|std=64ms")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
