"""Fig. 4: real-system evaluation — per-workload speedups, single vs
multi-core, AL-DRAM 55C timings vs DDR3 standard.

Paper: memory-intensive multi-core avg +14.0%, non-intensive +2.9%,
all-35 multi-core avg +10.5%, best (STREAM) up to +20.5%.
"""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import perf_model


def run(fast: bool = False) -> dict:
    with timed() as t:
        res = perf_model.evaluate(n=2048 if fast else 8192)
    s = res["summary"]
    emit("fig4_system_speedup", t.us,
         "mem-intensive={:.1%}(paper 14.0%)|non-int={:.1%}(2.9%)|"
         "all35={:.1%}(10.5%)|best={}:{:.1%}(20.5%)".format(
             s["multi_intensive_gmean"], s["multi_nonintensive_gmean"],
             s["multi_all_gmean"], s["best_multi"][0], s["best_multi"][1]))
    return res


if __name__ == "__main__":
    import json
    r = run()
    print(json.dumps(r["summary"], indent=1, default=str))
