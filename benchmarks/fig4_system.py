"""Fig. 4: real-system evaluation — per-workload speedups, single vs
multi-core, AL-DRAM 55C timings vs DDR3 standard, plus the
profiled-table variant that closes the loop from the profiler's own
TimingTable to per-temperature-bin system speedups.

Paper: memory-intensive multi-core avg +14.0%, non-intensive +2.9%,
all-35 multi-core avg +10.5%, best (STREAM) up to +20.5%.

Both benches ride the batched `SimEngine` campaign: one trace-synthesis
dispatch plus one replay dispatch, regardless of how many workloads,
core modes, timing rows or temperature bins the grid spans (the
``dispatches=`` field in the derived CSV column is asserted by CI).
"""

from __future__ import annotations

from benchmarks.common import emit, population, profiler, timed
from repro.core import perf_model
from repro.core.sim_engine import SimEngine


def run(fast: bool = False) -> dict:
    engine = SimEngine()
    with timed() as t:
        res = perf_model.evaluate(n=2048 if fast else 8192, engine=engine)
    s = res["summary"]
    emit("fig4_system_speedup", t.us,
         "mem-intensive={:.1%}(paper 14.0%)|non-int={:.1%}(2.9%)|"
         "all35={:.1%}(10.5%)|best={}:{:.1%}(20.5%)|dispatches={}".format(
             s["multi_intensive_gmean"], s["multi_nonintensive_gmean"],
             s["multi_all_gmean"], s["best_multi"][0], s["best_multi"][1],
             res["dispatches"]["total"]))
    return res


def run_profiled(fast: bool = False) -> dict:
    """Temperature-resolved Fig. 4 from a profiled TimingTable: profile
    the population, then replay the workload pool under every bin's
    all-module-safe timing row in one batched campaign."""
    from repro.core.aldram import ALDRAMController
    pop = population(fast)
    ctrl = ALDRAMController(profiler(fast))
    engine = SimEngine()
    with timed() as t:
        ctrl.profile(pop)
        res = ctrl.evaluate_system(pop, n=1024 if fast else 4096,
                                   engine=engine)
    cool, hot = res["temps"][0], res["temps"][-1]
    emit("fig4_profiled_table", t.us,
         "bins={}|all35@{:.0f}C={:.1%}|all35@{:.0f}C={:.1%}|"
         "intensive@{:.0f}C={:.1%}|replay_dispatches={}".format(
             len(res["temps"]), cool,
             res["per_temp"][cool]["multi_all_gmean"], hot,
             res["per_temp"][hot]["multi_all_gmean"], cool,
             res["per_temp"][cool]["multi_intensive_gmean"],
             engine.dispatch_count))
    return res


if __name__ == "__main__":
    import json
    r = run()
    print(json.dumps(r["summary"], indent=1, default=str))
    rp = run_profiled(fast=True)
    print(json.dumps(rp["per_temp"], indent=1, default=str))
