"""ThermalEngine benchmark: the ONLINE half of AL-DRAM (paper Sec. 4).

Replays the full workload pool with the controller's bin-switching
logic running inside the traced scan, under the stock dynamic thermal
scenarios (steady / diurnal ramp / cooling failure / bursty), and
reports three deployments per scenario:

  * adaptive          — in-scan selection over the profiled table
                        stack, with hysteresis,
  * static-worst-case — one register set provisioned for the
                        scenario's peak sensed temperature,
  * oracle            — zero-hysteresis adaptive (upper bound).

The whole campaign — 35 workloads x 2 core modes x (scenarios +
oracle variants) x (adaptive + static brackets) — costs exactly ONE
traced dispatch: the trace pool rides as a declarative `SynthSpec`
(synthesis fused into the launch) and `SimEngine.run_bracket` runs
the adaptive replay, the on-device worst-bin round-up AND the static
bracket in the same dispatch (`evaluate_dynamic(fused=True)`).  The
``dispatches=1`` field in the derived CSV column is asserted by CI.
The bench also asserts the acceptance bracket: adaptive >=
static-worst-case on every dynamic scenario.
"""

from __future__ import annotations

from benchmarks.common import emit, population, profiler, timed


def run(fast: bool = False) -> dict:
    from repro.core import perf_model
    from repro.core.aldram import ALDRAMController
    from repro.core.sim_engine import SimEngine

    pop = population(fast)
    ctrl = ALDRAMController(profiler(fast))
    engine = SimEngine()
    with perf_model.synth_dispatch_scope() as scope:
        with timed() as t:
            ctrl.profile(pop)
            res = ctrl.evaluate_dynamic(pop, n=1024 if fast else 4096,
                                        engine=engine, fused=True)
    dispatches = engine.dispatch_count + scope.count
    per = res["per_scenario"]
    # the acceptance bracket must hold for EVERY policy of the
    # campaign, not just the headline view
    for pd in res["per_policy"]:
        for name, d in pd.items():
            assert d["adaptive_gmean"] >= d["static_worst_gmean"] - 1e-9, \
                (name, d)
    parts = ["{}:adapt={:.1%}/static={:.1%}/oracle={:.1%}".format(
        name, d["adaptive_gmean"], d["static_worst_gmean"],
        d["oracle_gmean"]) for name, d in per.items()]
    emit("thermal_adaptive_replay", t.us,
         "|".join(parts) + f"|dispatches={dispatches}")
    res["dispatches"] = {"total": dispatches}
    return res


if __name__ == "__main__":
    import json
    r = run(fast=True)
    print(json.dumps(r["per_scenario"], indent=1))
