"""Benchmark harness: one function per paper table/figure plus the
framework/roofline benches.  Prints ``name,us_per_call,derived`` CSV
and writes a machine-readable ``BENCH_<name>.json`` summary per bench
(wall time, dispatch counts, headline stats) to the REPO ROOT by
default, so the perf trajectory is tracked across PRs (committed
baselines; CI also uploads them as workflow artifacts and gates the
sim_bench fast wall time against the committed baseline).

  python -m benchmarks.run [--fast] [--only NAME] [--out-dir DIR]
                           [--repeat N] [--baseline DIR]
                           [--baseline-factor F]

``--repeat N`` runs each bench N times and reports the MEDIAN wall
time (the per-run walls are kept in the summary), so one-off noise on
shared runners doesn't pollute the trajectory.

``--baseline DIR`` compares each bench's median wall time against the
committed ``BENCH_<name>.json`` in DIR (e.g. the repo root) after the
run, prints a regression table, and exits non-zero when any bench
runs slower than ``--baseline-factor`` (default 2.0) times its
baseline — the same contract CI applies to the sim_bench fast path,
available locally for every bench.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time
import traceback

_MAX_DEPTH = 3
_MAX_ITEMS = 24
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _headline(obj, depth: int = 0):
    """Scalar-only projection of a bench's result dict: keeps the
    JSON-serializable headline numbers, drops arrays/traces/objects so
    the summaries stay diff-friendly."""
    if isinstance(obj, (bool, int, float, str)) or obj is None:
        return obj
    try:
        import numpy as np
        # numpy scalars are headline numbers too — convert BEFORE the
        # depth cutoff so np.float32 and float survive identically
        if isinstance(obj, np.generic):
            return obj.item()
    except Exception:  # noqa: BLE001
        pass
    if depth >= _MAX_DEPTH:
        return None
    if isinstance(obj, dict):
        out = {}
        for k, v in list(obj.items())[:_MAX_ITEMS]:
            hv = _headline(v, depth + 1)
            if hv is not None or v is None:
                out[str(k)] = hv
        return out or None
    if isinstance(obj, (list, tuple)):
        vals = [_headline(v, depth + 1) for v in obj[:_MAX_ITEMS]]
        vals = [v for v in vals if v is not None]
        return vals or None
    return None


def _write_summary(out_dir: str, name: str, walls: list[float],
                   fast: bool, result,
                   error: str | None = None) -> None:
    summary = {"name": name,
               "wall_s": round(statistics.median(walls), 6),
               "fast": fast, "error": error}
    if len(walls) > 1:
        summary["repeats"] = len(walls)
        summary["wall_s_all"] = [round(w, 6) for w in walls]
    if isinstance(result, dict):
        if "dispatches" in result:
            summary["dispatches"] = _headline(result["dispatches"])
        summary["headline"] = _headline(result)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced population / fewer samples")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out-dir", default=_REPO_ROOT,
                    help="directory for the BENCH_<name>.json summaries "
                         "(default: the repo root, so baselines are "
                         "committed and tracked across PRs)")
    ap.add_argument("--repeat", type=int, default=1,
                    help="run each bench N times; report the median "
                         "wall time")
    ap.add_argument("--baseline", default=None,
                    help="directory holding committed BENCH_<name>.json "
                         "baselines to regression-compare against")
    ap.add_argument("--baseline-factor", type=float, default=2.0,
                    help="fail when a bench's wall time exceeds "
                         "FACTOR x its baseline (default 2.0)")
    args = ap.parse_args()

    from benchmarks import (fault_bench, fig2_refresh, fig2_timing,
                            fig3_population, fig4_system, fig_bank,
                            fig_region, fleet_bench, framework,
                            multi_timing, power_bench, repeatability,
                            roofline, sim_bench, thermal_bench,
                            traffic_bench)

    benches = {
        "fig2_refresh": fig2_refresh.run,
        "fig2_timing": fig2_timing.run,
        "fig3_population": fig3_population.run,
        "fig4_system": fig4_system.run,
        "fig4_profiled": fig4_system.run_profiled,
        "fig_bank": fig_bank.run,
        "fig_region": fig_region.run,
        "sim_bench": sim_bench.run,
        "thermal_bench": thermal_bench.run,
        "power": power_bench.run,
        "repeatability": repeatability.run,
        "multi_timing": multi_timing.run,
        "fleet_bench": fleet_bench.run,
        "fault_bench": fault_bench.run,
        "traffic_bench": traffic_bench.run,
        "framework": framework.run,
        "roofline": roofline.run,
    }
    os.makedirs(args.out_dir, exist_ok=True)
    print("name,us_per_call,derived")
    failed = []
    measured: dict[str, float] = {}
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        walls, res, err = [], None, None
        for _ in range(max(1, args.repeat)):
            t0 = time.monotonic()
            try:
                res = fn(fast=args.fast)
            except Exception as e:  # noqa: BLE001
                err = f"{type(e).__name__}: {e}"
                print(f"{name},0,ERROR:{err}", flush=True)
                traceback.print_exc(file=sys.stderr)
            walls.append(time.monotonic() - t0)
            if err:
                break
        if err:
            failed.append(name)
        else:
            measured[name] = statistics.median(walls)
        _write_summary(args.out_dir, name, walls, args.fast, res,
                       error=err)
    if args.baseline:
        regressions = _compare_baseline(measured, args.baseline,
                                        args.baseline_factor,
                                        fast=args.fast)
        if regressions:
            raise SystemExit(f"wall-time regressions: {regressions}")
    if failed:
        raise SystemExit(f"failed: {failed}")


def _compare_baseline(measured: dict[str, float], baseline_dir: str,
                      factor: float, fast: bool = False) -> list[str]:
    """Print a wall-time table vs the committed baselines; return the
    benches slower than `factor` x baseline.  Benches without a
    committed baseline — or with an unreadable/malformed one, or one
    recorded under a different --fast mode — just WARN and skip (the
    run's own summaries are already written by this point; a missing
    or stale baseline must never fail the run).  The converse holds
    too: a baseline for a bench that did NOT run this time (renamed,
    removed, or filtered by --only) warns and is skipped — it must
    never gate either.  Only comparable entries gate."""
    regressions = []
    print(f"\nbaseline compare vs {baseline_dir} "
          f"(fail > {factor:g}x):", file=sys.stderr)
    try:
        stale = sorted(
            f[len("BENCH_"):-len(".json")]
            for f in os.listdir(baseline_dir)
            if f.startswith("BENCH_") and f.endswith(".json"))
    except OSError:
        stale = []
    for name in stale:
        if name not in measured:
            print(f"  {name}: baseline present but bench did not run "
                  f"this time — skipped", file=sys.stderr)
    for name, wall in measured.items():
        path = os.path.join(baseline_dir, f"BENCH_{name}.json")
        try:
            with open(path) as f:
                base = json.load(f)
        except (OSError, ValueError):
            print(f"  {name}: {wall:.3f}s (no baseline)",
                  file=sys.stderr)
            continue
        if not isinstance(base, dict):
            print(f"  {name}: {wall:.3f}s (malformed baseline)",
                  file=sys.stderr)
            continue
        if bool(base.get("fast")) != bool(fast):
            print(f"  {name}: {wall:.3f}s (baseline from different "
                  f"--fast mode)", file=sys.stderr)
            continue
        base_wall = base.get("wall_s")
        if not base_wall:
            print(f"  {name}: {wall:.3f}s (baseline has no wall_s)",
                  file=sys.stderr)
            continue
        ratio = wall / base_wall
        flag = " REGRESSION" if ratio > factor else ""
        print(f"  {name}: {wall:.3f}s vs {base_wall:.3f}s "
              f"({ratio:.2f}x){flag}", file=sys.stderr)
        if ratio > factor:
            regressions.append(name)
    return regressions


if __name__ == "__main__":
    main()
