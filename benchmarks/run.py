"""Benchmark harness: one function per paper table/figure plus the
framework/roofline benches.  Prints ``name,us_per_call,derived`` CSV.

  python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced population / fewer samples")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (fig2_refresh, fig2_timing, fig3_population,
                            fig4_system, framework, multi_timing,
                            power_bench, repeatability, roofline)

    benches = {
        "fig2_refresh": fig2_refresh.run,
        "fig2_timing": fig2_timing.run,
        "fig3_population": fig3_population.run,
        "fig4_system": fig4_system.run,
        "fig4_profiled": fig4_system.run_profiled,
        "power": power_bench.run,
        "repeatability": repeatability.run,
        "multi_timing": multi_timing.run,
        "framework": framework.run,
        "roofline": roofline.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        try:
            fn(fast=args.fast)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"failed: {failed}")


if __name__ == "__main__":
    main()
