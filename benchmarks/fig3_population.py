"""Fig. 3 + Sec. 5.2: the 115-module population analysis.

Paper targets:
  3a/3b refresh envelopes: most modules far above 64 ms.
  3c read  latency: -21.1% @85C, -32.7% @55C on average.
  3d write latency: -34.4% @85C, -55.1% @55C on average.
  per-parameter averages @55C: tRCD 17.3 / tRAS 37.7 / tWR 54.8 /
  tRP 35.2 %; @85C: 15.6 / 20.4 / 20.6 / 28.5 %.
"""

from __future__ import annotations

from benchmarks.common import emit, population, profiler, timed
from repro.core.sweep import Op

TEMPS = (85.0, 55.0)


def run(fast: bool = False) -> dict:
    pop = population(fast)
    prof = profiler(fast)
    out: dict = {}
    with timed() as t:
        # the 115-module campaign: one refresh dispatch (both ops), one
        # fused (85C, 55C) x (read, write) timing dispatch
        rp_read, rp_write = prof.refresh_campaign(pop, 85.0)
        out["refresh"] = {
            "read_min_ms": float(rp_read.per_module.min()),
            "read_median_ms": float(sorted(rp_read.per_module)
                                    [pop.n_modules // 2]),
            "write_median_ms": float(sorted(rp_write.per_module)
                                     [pop.n_modules // 2]),
        }
        res = prof.engine.sweep(pop,
                                prof.campaign_spec(TEMPS, rp_read, rp_write))
        all_r = res.reductions(Op.READ)
        all_w = res.reductions(Op.WRITE)
        for ti, temp in enumerate(TEMPS):
            red_r, red_w = all_r[ti], all_w[ti]
            out[f"t{int(temp)}"] = {
                "read_sum": red_r["latency_sum"],
                "write_sum": red_w["latency_sum"],
                "trcd": red_r["trcd"], "tras": red_r["tras"],
                "twr": red_w["twr"], "trp": red_r["trp"],
                "allsafe": {k: red_r[f"{k}_allsafe"]
                            for k in ("trcd", "tras", "trp")}
                | {"twr": red_w["twr_allsafe"]},
            }
    emit("fig3_population", t.us,
         "read55={:.1%}(paper 32.7%)|write55={:.1%}(paper 55.1%)|"
         "read85={:.1%}(21.1%)|write85={:.1%}(34.4%)".format(
             out["t55"]["read_sum"], out["t55"]["write_sum"],
             out["t85"]["read_sum"], out["t85"]["write_sum"]))
    emit("sec52_param_reductions_55C", t.us,
         "tRCD={:.1%}(17.3)|tRAS={:.1%}(37.7)|tWR={:.1%}(54.8)|"
         "tRP={:.1%}(35.2)".format(
             out["t55"]["trcd"], out["t55"]["tras"],
             out["t55"]["twr"], out["t55"]["trp"]))
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
