"""Fault-injection benchmark: the watchdog's bounded-error /
latency-give-back contract (ISSUE 9; `repro.core.faults`).

Replays ONE adaptive campaign — (traces x policies x {tuned, JEDEC}
tables x thermal scenarios x fault rows) — in a single traced
dispatch.  The fault axis is a (mode x severity x watchdog) grid over
a cold-reading sensor: a sensor that reads LOW (stuck-at or drifting
calibration) makes the controller keep the aggressive cold-bin rows
through the hot bursts of the `bursty` ambient, so margin-conditioned
read errors arrive in episodes.  Each faulted (mode, severity) pair
appears twice:

  * watchdog OFF — every detected error pays the retry surcharge and
    the silent-corruption counter accumulates for as long as the hot
    burst lasts: nothing in the loop stops it, so the count scales
    with the burst-request total (unbounded in trace length),
  * watchdog ON  — the cumulative detected-error budget trips a
    sticky degradation to the JEDEC fallback row mid-burst; every
    32nd degraded request probes the adaptive row, and two
    consecutive clean probes (the burst has passed) recover it.

The bench asserts the acceptance bracket of the fault subsystem:

  * the whole fault grid is exactly ONE SimEngine dispatch
    (`dispatches=1` in the derived CSV line, grepped by CI),
  * the watchdog detected-error bound is EXACT in every grid cell —
    ``detected <= wd_err_n * (trips + 1) + probes`` — the
    `wd_bound=exact` token CI greps,
  * every watchdog-on lane shows >= 10x fewer silent corruptions and
    a lower effective error rate than its watchdog-off twin, at
    <= 2 points of timing reduction given back vs the fault-free
    lane (the give-back is the probe cadence: ~2x32 requests of
    post-burst recovery lag plus the priced retries).

Timing reduction is measured in-grid: the K axis carries the tuned
table AND an all-JEDEC table, so the JEDEC reference latency comes
from the same dispatch (`red = 1 - lat_tuned / lat_jedec`, fault-free
JEDEC lane as denominator).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed


def _mk_trace(n: int, seed: int):
    from repro.core.dram_sim import Trace
    r = np.random.default_rng(seed)
    t = np.cumsum(r.uniform(2.0, 14.0, n)).astype(np.float32)
    return Trace(t, r.integers(0, 8, n).astype(np.int32),
                 r.integers(0, 64, n).astype(np.int32),
                 (r.uniform(size=n) < 0.3))


def _fault_grid(fast: bool, span_ns: float):
    """none + (mode x severity x {off, wd}) fault rows; returns the
    FaultSpec plus the (mode, severity) -> (f_off, f_wd) lane map.

    Both modes read LOW — a sensor stuck cold from t=0 and one that
    dies mid-service (`stuck_from_ns` at 40% of the trace) — so every
    mis-bin picks a row MORE aggressive than the truth (the dangerous
    direction)."""
    from repro.core import faults
    modes = {"stuck": dict(stuck_c=40.0, stuck_from_ns=0.0)}
    if not fast:
        modes["latched"] = dict(stuck_c=40.0,
                                stuck_from_ns=0.4 * span_ns)
    sevs = {"mild": 0.03, "severe": 0.08}
    rows = [faults.FaultScenario(name="none")]
    lanes = {}
    for m, mkw in modes.items():
        for s, bin_c in sevs.items():
            base = dict(err_bin_c=bin_c, err_scale=0.0,
                        detect_frac=0.75, retry_ns=60.0, **mkw)
            lanes[(m, s)] = (len(rows), len(rows) + 1)
            rows.append(faults.FaultScenario(name=f"{m}.{s}", **base))
            rows.append(faults.FaultScenario(
                name=f"{m}.{s}.wd", wd_err_n=4, wd_probe=32,
                wd_recover_n=2, **base))
    return faults.FaultSpec(scenarios=tuple(rows), seed=11), lanes


def run(fast: bool = False) -> dict:
    from repro.core.dram_sim import OPEN_FCFS, Policy
    from repro.core.sim_engine import SimEngine, SimSpec
    from repro.core.thermal import ThermalSpec, bursty, steady
    from repro.core.timing import TimingParams

    n = 2048 if fast else 4096
    traces = (_mk_trace(n, 1), _mk_trace(n - n // 8, 2))
    span_ns = float(np.asarray(traces[0].arrival)[-1])
    pols = (OPEN_FCFS,) if fast else (OPEN_FCFS, Policy(page="closed"))

    jedec = TimingParams(trcd=13.75, tras=35.0, twr=15.0, trp=13.75)
    # hot bins serve JEDEC outright: the adaptive win is the cold bins
    tuned = np.stack([
        TimingParams(trcd=10.0, tras=27.0, twr=11.0, trp=10.0).as_row(),
        TimingParams(trcd=12.0, tras=31.0, twr=13.0, trp=12.0).as_row(),
        jedec.as_row(), jedec.as_row()])
    tables = np.stack([tuned, np.tile(jedec.as_row(), (4, 1))])

    # cool control + hot bursts: 2 bursts per trace, 20% duty
    scens = (steady(50.0), bursty(48.0, 30.0, span_ns / 2.0, duty=0.2))
    if not fast:
        scens += (bursty(44.0, 34.0, span_ns / 3.0, duty=0.2),)
    thermal = ThermalSpec(scenarios=scens, temp_bins=(55.0, 70.0, 85.0))

    fspec, lanes = _fault_grid(fast, span_ns)
    engine = SimEngine()
    d0 = engine.dispatch_count
    spec = SimSpec(traces=traces, timings=tables, policies=pols,
                   thermal=thermal, faults=fspec)
    with timed() as t:
        res = engine.run(spec)
        np.asarray(res.mean_latency_ns)  # block until the grid lands
    dispatches = engine.dispatch_count - d0
    assert dispatches == 1, dispatches

    lat = res.mean_latency_ns                      # [T, P, K, C, F]
    lat_j = lat[:, :, 1, :, 0]                     # JEDEC table, no fault
    red = 1.0 - lat[:, :, 0] / lat_j[..., None]    # [T, P, C, F]
    red_f = red.mean(axis=(0, 1, 2))               # [F] reduction points

    wd_n = np.asarray(fspec.pack()[:, 12])         # WD_ERR_N per lane
    det, sil = res.detected_errors, res.silent_errors
    trips, probes = res.wd_trips, res.wd_probes
    # the watchdog bound is EXACT in every grid cell of every wd lane
    bound = wd_n * (trips + 1) + probes
    wd_on = wd_n > 0
    assert (det[..., wd_on] <= bound[..., wd_on]).all(), \
        "watchdog detected-error bound violated"

    n_req = (sum(tr.arrival.shape[0] for tr in traces)
             * len(pols) * len(scens))
    tuned_cnt = lambda a, f: int(a[:, :, 0, :, f].sum())  # noqa: E731

    assert tuned_cnt(det, 0) == 0 and tuned_cnt(sil, 0) == 0
    pairs, parts = {}, []
    for (m, s), (f_off, f_wd) in lanes.items():
        sil_off, sil_on = tuned_cnt(sil, f_off), tuned_cnt(sil, f_wd)
        det_off, det_on = tuned_cnt(det, f_off), tuned_cnt(det, f_wd)
        gb = float(red_f[0] - red_f[f_wd]) * 100.0  # points given back
        ratio = sil_off / max(sil_on, 1)
        rate_off = (det_off + sil_off) / n_req
        rate_on = (det_on + sil_on) / n_req
        # watchdog-off keeps accumulating; watchdog-on is clamped
        assert sil_off >= 50, (m, s, sil_off)
        assert ratio >= 10.0, (m, s, sil_off, sil_on)
        assert gb <= 2.0, (m, s, gb)
        assert rate_on < rate_off, (m, s)
        pairs[f"{m}.{s}"] = {
            "silent_off": sil_off, "silent_on": sil_on,
            "detected_off": det_off, "detected_on": det_on,
            "trips": tuned_cnt(trips, f_wd),
            "probes": tuned_cnt(probes, f_wd),
            "err_rate_off": round(rate_off, 5),
            "err_rate_on": round(rate_on, 5),
            "giveback_pt": round(gb, 3),
            "silent_ratio": round(ratio, 1)}
        parts.append(f"{m}.{s}:sil {sil_off}->{sil_on}"
                     f"/gb={gb:.2f}pt/x{ratio:.0f}")

    emit("fault_grid", t.us,
         "none:red={:.1%}|".format(float(red_f[0])) + "|".join(parts)
         + f"|wd_bound=exact|dispatches={dispatches}")

    return {
        "reduction_none": float(red_f[0]),
        "pairs": pairs,
        "grid": {"traces": len(traces), "policies": len(pols),
                 "tables": 2, "scenarios": len(scens),
                 "faults": len(fspec), "requests": n_req},
        "dispatches": {"replay": dispatches, "total": dispatches},
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(fast=True), indent=1))
