"""Shared benchmark utilities: timed sections, the full simulated
population, CSV emission (name,us_per_call,derived)."""

from __future__ import annotations

import dataclasses
import time

import jax

from repro.core.calibration import (CALIBRATED_CONSTANTS,
                                    CALIBRATED_VARIATION)
from repro.core.profiler import Profiler
from repro.core.variation import sample_population

_POP_CACHE = {}


def population(fast: bool = False, seed: int = 0):
    key = (fast, seed)
    if key not in _POP_CACHE:
        cfg = CALIBRATED_VARIATION
        if fast:
            cfg = dataclasses.replace(cfg, n_modules=24, n_cells=8)
        _POP_CACHE[key] = sample_population(jax.random.PRNGKey(seed), cfg)
    return _POP_CACHE[key]


def profiler(fast: bool = False) -> Profiler:
    return Profiler(constants=CALIBRATED_CONSTANTS,
                    grid_step=2.5 if fast else 1.25)


def spatial_campaign(fast: bool, evaluate, regions: int = 1):
    """The ONE spatial-table campaign assembly `fig_bank` and
    `fig_region` share: profile the shared population with a per-bank
    (optionally subarray-region) controller, run one system evaluation
    through a fresh `SimEngine`, and count EVERY traced dispatch the
    comparison cost (replay + fused synthesis).

    `evaluate(ctrl, pop, engine, n)` runs the whole comparison through
    `engine` with `n` requests per workload.  Returns
    (controller, result, dispatches, wall_us)."""
    from repro.core import perf_model
    from repro.core.aldram import ALDRAMController
    from repro.core.sim_engine import SimEngine

    pop = population(fast)
    ctrl = ALDRAMController(profiler(fast), regions=regions)
    engine = SimEngine()
    s0 = perf_model.synth_dispatch_count
    with timed() as t:
        ctrl.profile(pop)
        res = evaluate(ctrl, pop, engine, 1024 if fast else 4096)
    dispatches = engine.dispatch_count + (perf_model.synth_dispatch_count
                                          - s0)
    return ctrl, res, dispatches, t.us


class timed:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.us = (time.monotonic() - self.t0) * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.0f},{derived}", flush=True)
