"""Fig. 2b/2c: error-free timing-parameter combinations for the
representative module at its safe refresh interval, 55C vs 85C.

Paper: read latency sum reducible 24% @85C / 36% @55C; write 35% / 47%.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, population, profiler, timed
from repro.core import timing as T


def run(fast: bool = False) -> dict:
    pop = population(fast)
    prof = profiler(fast)
    out = {}
    with timed() as t:
        rp = {op: prof.refresh_profile(pop, 85.0, op)
              for op in ("read", "write")}
        med = int(np.argsort(rp["read"].per_module)
                  [pop.n_modules // 2])
        for op, base in (("read", T.DDR3_1600.read_sum()),
                         ("write", T.DDR3_1600.write_sum())):
            for temp in (85.0, 55.0):
                tp = prof.timing_profile(pop, temp, op, rp[op].safe)
                red = 1 - tp.latency_sum[med] / base
                n_pass = int(tp.pass_per_module[med].sum())
                out[f"{op}_{int(temp)}"] = {
                    "latency_reduction": float(red),
                    "passing_combos": n_pass,
                    "chosen": tp.combos[med, :4].tolist(),
                }
    emit("fig2bc_timing_combos", t.us,
         "read 85/55C={:.0%}/{:.0%}(paper 24/36%)|write={:.0%}/{:.0%}"
         "(paper 35/47%)".format(
             out["read_85"]["latency_reduction"],
             out["read_55"]["latency_reduction"],
             out["write_85"]["latency_reduction"],
             out["write_55"]["latency_reduction"]))
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
