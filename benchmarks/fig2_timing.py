"""Fig. 2b/2c: error-free timing-parameter combinations for the
representative module at its safe refresh interval, 55C vs 85C.

Paper: read latency sum reducible 24% @85C / 36% @55C; write 35% / 47%.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, population, profiler, timed
from repro.core import timing as T
from repro.core.sweep import Op

TEMPS = (85.0, 55.0)


def run(fast: bool = False) -> dict:
    pop = population(fast)
    prof = profiler(fast)
    out = {}
    with timed() as t:
        rp_read, rp_write = prof.refresh_campaign(pop, 85.0)
        med = int(np.argsort(rp_read.per_module)[pop.n_modules // 2])
        # the whole (op x temperature) campaign is ONE fused dispatch
        res = prof.engine.sweep(pop,
                                prof.campaign_spec(TEMPS, rp_read, rp_write))
        for op, base in ((Op.READ, T.DDR3_1600.read_sum()),
                         (Op.WRITE, T.DDR3_1600.write_sum())):
            k = res.index(op)
            for ti, temp in enumerate(TEMPS):
                red = 1 - res.latency_sum[k][med, ti] / base
                n_pass = int(res.ok[k][med, ti].sum())
                out[f"{op.value}_{int(temp)}"] = {
                    "latency_reduction": float(red),
                    "passing_combos": n_pass,
                    "chosen": res.chosen[k][med, ti, :4].tolist(),
                }
    emit("fig2bc_timing_combos", t.us,
         "read 85/55C={:.0%}/{:.0%}(paper 24/36%)|write={:.0%}/{:.0%}"
         "(paper 35/47%)".format(
             out["read_85"]["latency_reduction"],
             out["read_55"]["latency_reduction"],
             out["write_85"]["latency_reduction"],
             out["write_55"]["latency_reduction"]))
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
