"""Sharded multi-channel campaign engine: the "heavy traffic" bench.

The paper's system evaluation measures real multi-core machines where
many masters contend through the memory controller onto multiple
channels and ranks.  This bench replays that shape at fleet scale —
multi-TENANT traffic (`perf_model.tenant_spec`: every stream a
Dirichlet mixture over the 70-entry workload pool, each tenant with
its own Poisson/bursty/diurnal arrival process) x address-INTERLEAVE
policies (row / cacheline / bank-XOR) x stacked timing rows (JEDEC
standard down to AL-DRAM-reduced), under 1/2/4 memory CHANNELS — and
the whole (tenants x interleaves x rows) grid for one channel count
is ONE sharded replay dispatch:

  * the tenant-mix synthesis fuses INTO the dispatch (the `TenantSpec`
    is a static jit arg; `synth_dispatch_count` never moves),
  * per-channel bank state and bus contention are priced in-scan
    ([C*R*B] packed controller state, zero extra dispatches),
  * the (trace x tenant-mix) leading axis shards across the campaign
    mesh (`launch.mesh.make_campaign_mesh` — every visible device),
    each device synthesizing and replaying only its shard, with only
    [grid]-shaped masked stats crossing the boundary.

Reported: end-to-end throughput (replayed requests/s of the headline
multi-channel campaign), mean/p99 latency per channel count, and the
adaptive-vs-static gap under contention — the latency ratio of the
JEDEC standard row to the most-reduced (AL-DRAM evaluation-scale) row,
which widens as channel contention shrinks the queueing share of
latency that timing reduction cannot touch.  The bench asserts the
acceptance contract: `dispatches=1` per campaign run, zero synthesis
launches, and the sharded masked stats matching an unsharded
single-device reference run within 1e-5 relative.

CI runs ``--fast`` under ``--xla_force_host_platform_device_count=4``
and greps the ``dispatches=1`` CSV field and the per-device
``shard=DxTxN`` shape.
"""

from __future__ import annotations

import statistics
import time

import numpy as np

from benchmarks.common import emit

CHANNEL_SWEEP = (1, 2, 4)


def run(fast: bool = False) -> dict:
    import jax

    from repro.core import perf_model
    from repro.core.dram_sim import Policy
    from repro.core.sim_engine import SimEngine, SimSpec
    from repro.core.timing import DDR3_1600, stack_timing
    from repro.launch.mesh import make_campaign_mesh

    n = 1024 if fast else 8192
    n_streams = 8 if fast else 16
    n_rows = 4 if fast else 8
    reps = 2 if fast else 3

    tenants = perf_model.tenant_spec(n=n, n_streams=n_streams, seed=0)
    # JEDEC standard (row 0) down to the AL-DRAM evaluation scale —
    # the static-vs-adaptive provisioning bracket under contention
    rows = stack_timing([DDR3_1600.scaled(f, f, f, f)
                         for f in np.linspace(1.0, 0.68, n_rows)])
    policies = (Policy(reorder_window=16, interleave="row"),
                Policy(reorder_window=16, interleave="cacheline"),
                Policy(reorder_window=16, interleave="bank_xor"))

    mesh = make_campaign_mesh()                    # all visible devices
    eng = SimEngine(mesh=mesh)
    ref_eng = SimEngine()                          # unsharded reference

    per_c: dict[int, dict] = {}
    walls: dict[int, float] = {}
    res_by_c: dict[int, object] = {}
    for n_ch in CHANNEL_SWEEP:
        spec = SimSpec(traces=tenants, timings=rows, policies=policies,
                       n_channels=n_ch)
        eng.run(spec)                        # untimed compile warm-up
        d0 = eng.dispatch_count
        s0 = perf_model.synth_dispatch_count
        t = []
        for _ in range(reps):
            t0 = time.monotonic()
            res = eng.run(spec)
            t.append(time.monotonic() - t0)
        replays = eng.dispatch_count - d0
        synths = perf_model.synth_dispatch_count - s0
        # the acceptance contract: ONE sharded replay dispatch per
        # campaign run, synthesis fused in (no separate launch)
        assert replays == reps and synths == 0, (replays, synths)
        walls[n_ch] = statistics.median(t)
        res_by_c[n_ch] = res
        mean = res.mean_latency_ns            # [T, P, S]
        p99 = res.p99_latency_ns
        per_c[n_ch] = {
            "mean_ns": float(mean.mean()),
            "p99_ns": float(p99.mean()),
            "wall_s": walls[n_ch],
            # JEDEC row vs the most-reduced row: what timing
            # adaptation still buys once channel contention is priced
            "static_vs_adaptive_gap": float(mean[..., 0].mean()
                                            / mean[..., -1].mean()),
        }

    # sharded stats must match the unsharded single-device reference
    n_ch_head = CHANNEL_SWEEP[-1]
    spec_head = SimSpec(traces=tenants, timings=rows,
                        policies=policies, n_channels=n_ch_head)
    res_ref = ref_eng.run(spec_head)
    res_sh = res_by_c[n_ch_head]
    rel = max(
        float(np.abs(res_sh.mean_latency_ns
                     / res_ref.mean_latency_ns - 1.0).max()),
        float(np.abs(res_sh.p99_latency_ns
                     / res_ref.p99_latency_ns - 1.0).max()))
    assert rel <= 1e-5, rel

    n_dev, t_local, n_local = eng.shard_shape
    grid = n_streams * len(policies) * n_rows
    requests = grid * n
    med = walls[n_ch_head]
    throughput = requests / med
    gap1 = per_c[CHANNEL_SWEEP[0]]["static_vs_adaptive_gap"]
    gapc = per_c[n_ch_head]["static_vs_adaptive_gap"]

    emit("traffic_campaign", med * 1e6,
         "requests={}|grid={}x{}x{}|n={}|channels={}|devices={}|"
         "shard={}x{}x{}|throughput={:.2f}Mreq/s|"
         "p99_c1={:.1f}ns|p99_c{}={:.1f}ns|gap_c1={:.2f}x|"
         "gap_c{}={:.2f}x|sharded_rel={:.0e}|dispatches=1".format(
             requests, n_streams, len(policies), n_rows, n,
             n_ch_head, n_dev, n_dev, t_local, n_local,
             throughput / 1e6,
             per_c[CHANNEL_SWEEP[0]]["p99_ns"], n_ch_head,
             per_c[n_ch_head]["p99_ns"], gap1, n_ch_head, gapc, rel))
    return {
        "requests": requests, "n": n, "n_streams": n_streams,
        "n_rows": n_rows, "interleaves": len(policies),
        "devices": n_dev,
        "shard_shape": list(eng.shard_shape),
        "throughput_req_s": throughput,
        "per_channel": {str(c): per_c[c] for c in CHANNEL_SWEEP},
        "gap_contention_slope": gapc - gap1,
        "sharded_rel_err": rel,
        "wall_s": med,
        "dispatches": {"replay_per_run": 1, "synth": 0},
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(fast=True), indent=1))
