"""Fleet recalibration benchmark: the errors-avoided vs latency-given-
back frontier (ROADMAP item 3; `repro.fleet`).

Runs the SAME drifting fleet-month (identical population, drift seed,
epoch temperatures, and a mid-month module failure) under the three
serving policies:

  * static-forever — the paper's one-shot deployment: profile once,
    never look again.  Keeps all of the profiled latency reduction and
    accumulates ECC events as drift pushes tail cells negative.
  * periodic       — full re-profile of the drifted population every
    `recal_period` epochs (straggler modules fall back to JEDEC rows
    for the epoch their install misses).
  * error-driven   — scrub-then-react: guardband tighten steps on the
    implicated rows, escalation to re-profile / JEDEC fallback, and
    probe-confirmed relaxation after clean streaks.

The bench asserts the acceptance bracket of the fleet subsystem:

  * serving is exactly ONE SimEngine replay dispatch per epoch for
    every policy (`replay_per_epoch=1` in the derived CSV column, and
    the trailing `dispatches=` total, are both grepped by CI),
  * the error-driven policy serves ZERO uncorrectable events — exactly
    0.0, not a tolerance (`monitor.ecc_events` gates on the integer
    failing-cell count) — while static-forever accumulates them,
  * error-driven strictly dominates static-forever on EFFECTIVE
    latency reduction (raw reduction minus ECC event penalties).
"""

from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit, profiler, timed
from repro.core.calibration import CALIBRATED_VARIATION
from repro.core.variation import sample_population


def run(fast: bool = False) -> dict:
    from repro.fleet.recal import FleetSpec, frontier, run_policies

    var_cfg = dataclasses.replace(
        CALIBRATED_VARIATION,
        n_modules=8 if fast else 16,
        n_cells=4 if fast else 6)
    pop = sample_population(jax.random.PRNGKey(7), var_cfg)
    spec = FleetSpec(n_epochs=30,
                     workload_rows=(0, 19) if fast else (0, 17, 19),
                     n_requests=512 if fast else 1024,
                     module_failures=((10, 3),),
                     seed=0)

    with timed() as t:
        results = run_policies(pop, spec, var_cfg=var_cfg,
                               profiler=profiler(fast))
        fr = frontier(results)

    # ---- acceptance bracket (CI greps the emitted line) ----
    replay = {p: r.summary()["replay_per_epoch"]
              for p, r in results.items()}
    for p, rpe in replay.items():
        assert rpe == 1.0, (p, rpe)
    err = fr["policies"]["error"]
    sta = fr["policies"]["static"]
    assert err["total_unc"] == 0.0, err        # exactly zero, no tolerance
    assert sta["total_unc"] > 0.0, sta
    assert err["eff_reduction"] > sta["eff_reduction"], (err, sta)

    total_replay = sum(r.replay_dispatches for r in results.values())
    parts = ["{}:eff={:.1%}/unc={:.0f}".format(
        p, fr["policies"][p]["eff_reduction"],
        fr["policies"][p]["total_unc"]) for p in results]
    emit("fleet_frontier", t.us,
         "|".join(parts) + "|replay_per_epoch=1"
         + f"|dispatches={total_replay}")

    return {
        "frontier": fr["policies"],
        "summaries": fr["summaries"],
        "dispatches": {
            "replay_total": total_replay,
            "replay_per_epoch": 1.0,
            "margin": sum(r.margin_dispatches for r in results.values()),
        },
    }


if __name__ == "__main__":
    import json
    r = run(fast=True)
    print(json.dumps(r["frontier"], indent=1))
